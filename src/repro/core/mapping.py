"""Logical-page mapping (FTL view) and Conduit's data-placement model (§4.4).

All data is addressed at logical-page granularity; the L2P table tracks each
page's current physical residence.  Conduit extends each L2P entry with the
lazy-coherence triple (owner, state, version) — see §4.4 "Coherence".

The FTL also enforces NDP layout constraints: Flash-Cosmos requires all
operands of an in-flash MWS AND to live in pages of the *same flash block*;
we model this with a ``flash_block`` group id per page and a one-time
co-location (read+program) cost when the constraint is violated.
"""
from __future__ import annotations

import copy
import dataclasses
import itertools
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.isa import Location
from repro.hw.ssd_spec import SSDSpec


@dataclasses.dataclass
class PageEntry:
    pid: int
    location: Location = Location.FLASH
    owner: Location = Location.FLASH          # who holds the latest version
    dirty: bool = False
    version: int = 0                          # 1-byte monotone counter (§4.4)
    flash_block: int = -1                     # layout group for MWS AND
    channel: int = 0                          # home flash channel (parallelism)
    die: int = 0                              # home die (channel*dies+die_idx)
    name: str = ""
    l2p_cached: bool = True                   # DFTL: entry resident in DRAM?

    VERSION_MAX = 255

    def bump_version(self) -> None:
        # Paper: flush before wrap-around; we assert the flush happened.
        self.version = (self.version + 1) % (self.VERSION_MAX + 1)


class PageTable:
    """L2P mapping + Conduit coherence metadata + placement policy."""

    def __init__(self, spec: SSDSpec, l2p_cache_fraction: float = 0.9):
        self.spec = spec
        self.entries: Dict[int, PageEntry] = {}
        self._next_pid = itertools.count()
        self._next_block = itertools.count()
        self._nchan = spec.flash.channels
        self._ndies = spec.flash.channels * spec.flash.dies_per_channel
        self._alloc_cursor = 0
        # DFTL-style demand cache: a fraction of entries resident in DRAM.
        self.l2p_cache_fraction = l2p_cache_fraction
        self._initial: Dict[int, tuple] = {}

    # -- allocation ---------------------------------------------------------

    def alloc_array(self, nbytes: int, name: str = "",
                    location: Location = Location.FLASH) -> List[int]:
        """Allocate logical pages for an array; pages stripe across channels
        (internal parallelism) and share one flash block group per stripe set
        (Flash-Cosmos-friendly placement by the extended FTL, §5.1)."""
        psize = self.spec.page_size
        npages = max(1, -(-nbytes // psize))
        block = next(self._next_block)
        pids = []
        for i in range(npages):
            pid = next(self._next_pid)
            ent = PageEntry(
                pid=pid, location=location, owner=location,
                flash_block=block, channel=self._alloc_cursor % self._nchan,
                die=self._alloc_cursor % self._ndies,
                name=f"{name}[{i}]" if name else "",
                l2p_cached=(pid % 100) < int(self.l2p_cache_fraction * 100),
            )
            self._alloc_cursor += 1
            self.entries[pid] = ent
            pids.append(pid)
        return pids

    def reset(self) -> None:
        """Restore every page to its initial (post-load) placement so the
        same trace can be simulated under several policies independently."""
        for pid, snap in self._initial.items():
            ent = self.entries[pid]
            (ent.location, ent.owner, ent.dirty, ent.version,
             ent.flash_block, ent.l2p_cached, ent.channel, ent.die) = snap

    def snapshot_initial(self) -> None:
        self._initial = {
            pid: (e.location, e.owner, e.dirty, e.version,
                  e.flash_block, e.l2p_cached, e.channel, e.die)
            for pid, e in self.entries.items()}

    def clone(self) -> "PageTable":
        """Independent copy of the mutable residency state.

        Much cheaper than ``copy.deepcopy``: the spec is immutable and
        shared, the ``_initial`` snapshot values are tuples and shared,
        and only the :class:`PageEntry` records — the state a Simulation
        mutates — are duplicated.  This is the open-loop serving driver's
        per-session admission cost, so it sits on a measured path."""
        new = PageTable.__new__(PageTable)
        new.spec = self.spec
        new.entries = {pid: copy.copy(e) for pid, e in self.entries.items()}
        new._next_pid = copy.deepcopy(self._next_pid)
        new._next_block = copy.deepcopy(self._next_block)
        new._nchan = self._nchan
        new._ndies = self._ndies
        new._alloc_cursor = self._alloc_cursor
        new.l2p_cache_fraction = self.l2p_cache_fraction
        new._initial = dict(self._initial)
        return new

    def __getitem__(self, pid: int) -> PageEntry:
        return self.entries[pid]

    def __len__(self) -> int:
        return len(self.entries)

    # -- feature: operand location (L2P lookup, §4.5 latencies) -------------

    def lookup_latency_ns(self, pid: int) -> float:
        ent = self.entries[pid]
        if ent.l2p_cached:
            return self.spec.l2p_lookup_dram_ns
        # demand-fetch the mapping entry from flash, then it is cached
        ent.l2p_cached = True
        return self.spec.l2p_lookup_flash_ns

    def location(self, pid: int) -> Location:
        return self.entries[pid].location

    # -- coherence (§4.4) ----------------------------------------------------

    def record_write(self, pid: int, by: Location) -> None:
        """A computation resource modified the page: update owner/state/version."""
        ent = self.entries[pid]
        if ent.owner == by and ent.dirty:
            ent.bump_version()                  # same-owner update: version only
        else:
            ent.owner = by
            ent.dirty = True
            ent.bump_version()
        ent.location = by

    def commit(self, pid: int) -> bool:
        """Sync trigger: commit the latest version to flash; returns True if a
        flash program was actually needed (page was dirty off-flash)."""
        ent = self.entries[pid]
        needed = ent.dirty and ent.owner != Location.FLASH
        ent.owner = Location.FLASH
        ent.location = Location.FLASH
        ent.dirty = False
        ent.version = 0
        return needed

    def move(self, pid: int, to: Location) -> None:
        ent = self.entries[pid]
        ent.location = to

    # -- layout constraints ---------------------------------------------------

    def same_block(self, pids: Sequence[int]) -> bool:
        blocks = {self.entries[p].flash_block for p in pids}
        return len(blocks) <= 1

    def co_locate(self, pids: Sequence[int]) -> int:
        """Force pages into one flash block group (FTL relocation).  Returns
        the number of pages that had to be physically relocated."""
        if not pids:
            return 0
        target = self.entries[pids[0]].flash_block
        moved = 0
        for p in pids[1:]:
            ent = self.entries[p]
            if ent.flash_block != target:
                ent.flash_block = target
                moved += 1
        return moved

    # -- accounting -----------------------------------------------------------

    def dirty_pages(self) -> List[int]:
        return [p for p, e in self.entries.items() if e.dirty]

    def owner_counts(self) -> Dict[Location, int]:
        out: Dict[Location, int] = {}
        for e in self.entries.values():
            out[e.owner] = out.get(e.owner, 0) + 1
        return out
