"""input_specs: ShapeDtypeStruct stand-ins for every (arch x shape) cell.

Weak-type-correct, sharded, zero-allocation argument trees (params,
optimizer state, caches, batches) for the dry-run's ``.lower()`` — the
pattern that proves the distribution config is coherent without hardware.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.configs.shapes import SHAPES, ShapeSpec, applicable
from repro.launch import sharding as SH
from repro.launch.mesh import mesh_axes
from repro.launch.steps import (build_prefill_step, build_serve_step,
                                build_train_step, extra_inputs)
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.optim.adamw import adamw_init


def params_shapes(cfg: ArchConfig):
    return jax.eval_shape(lambda k: M.init_params(cfg, k),
                          jax.random.PRNGKey(0))


def opt_shapes(cfg: ArchConfig, p_shapes):
    return jax.eval_shape(adamw_init, p_shapes)


def cache_shapes(cfg: ArchConfig, batch: int, max_seq: int):
    return jax.eval_shape(
        functools.partial(M.init_cache, cfg, batch, max_seq))


def _sharded(tree_shapes, spec_tree, mesh):
    return SH.to_sds(tree_shapes, spec_tree, mesh)


def input_specs(arch: str, shape: str, mesh) -> Tuple[Callable, Tuple, str]:
    """Returns (step_fn, example_args_SDS, kind) for one cell.

    kind in {train, prefill, decode}.  Raises ValueError for inapplicable
    cells (long_500k on pure full-attention archs) with the skip reason.
    """
    cfg = configs.get(arch)
    spec: ShapeSpec = SHAPES[shape]
    ok, reason = applicable(cfg, shape)
    if not ok:
        raise ValueError(reason)
    data, model = mesh_axes(mesh)

    p_shapes = params_shapes(cfg)
    p_specs = SH.param_specs(cfg, p_shapes, mesh)
    params_sds = _sharded(p_shapes, p_specs, mesh)

    b, s = spec.global_batch, spec.seq_len

    def tok_sds(shape_, dtype=jnp.int32):
        return jax.ShapeDtypeStruct(
            shape_, dtype,
            sharding=NamedSharding(mesh, SH.batch_spec(shape_, mesh)))

    extras = extra_inputs(cfg, b, min(s, 4096) if spec.kind == "train" else s)

    def extras_sds():
        out = {}
        for k, v in extras.items():
            if k == "extra_embeds" or k == "enc_feats":
                out[k] = jax.ShapeDtypeStruct(
                    v.shape, v.dtype, sharding=NamedSharding(
                        mesh, SH.embeds_spec(v.shape, mesh)))
            else:
                out[k] = jax.ShapeDtypeStruct(
                    v.shape, v.dtype, sharding=NamedSharding(mesh, P()))
        return out

    if spec.kind == "train":
        o_shapes = opt_shapes(cfg, p_shapes)
        o_specs = jax.tree_util.tree_map_with_path(
            lambda path, leaf: SH.param_spec_for(
                path, leaf.shape, mesh, data, model)
            if leaf.ndim > 0 else P(),
            o_shapes)
        opt_sds = _sharded(o_shapes, o_specs, mesh)
        batch = {"tokens": tok_sds((b, s)), "labels": tok_sds((b, s))}
        batch.update(extras_sds())
        fn = build_train_step(cfg)
        return fn, (params_sds, opt_sds, batch), "train"

    if spec.kind == "prefill":
        n_extra = (extras["extra_embeds"].shape[1]
                   if "extra_embeds" in extras else 0)
        c_shapes = cache_shapes(cfg, b, s + n_extra)
        c_specs = SH.cache_specs(cfg, c_shapes, mesh)
        caches_sds = _sharded(c_shapes, c_specs, mesh)
        batch = {"tokens": tok_sds((b, s))}
        batch.update(extras_sds())
        fn = build_prefill_step(cfg)
        return fn, (params_sds, caches_sds, batch), "prefill"

    # decode: one new token against a seq_len-deep cache
    c_shapes = cache_shapes(cfg, b, s)
    c_specs = SH.cache_specs(cfg, c_shapes, mesh)
    caches_sds = _sharded(c_shapes, c_specs, mesh)
    token = tok_sds((b,))
    index = jax.ShapeDtypeStruct((), jnp.int32,
                                 sharding=NamedSharding(mesh, P()))
    fn = build_serve_step(cfg)
    args = [params_sds, caches_sds, token, index]
    if cfg.enc_layers:
        enc_shape = (b, max(8, min(s, 4096) // 4), cfg.d_model)
        args.append(jax.ShapeDtypeStruct(
            enc_shape, jnp.bfloat16,
            sharding=NamedSharding(mesh, SH.embeds_spec(enc_shape, mesh))))
    return fn, tuple(args), "decode"
