"""Launch layer: production mesh, sharding plans, step builders, dry-run."""
