"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state.  Single pod: 16x16 = 256 chips, (data, model).  Multi-pod:
2 pods x 256 = 512 chips, (pod, data, model) — the "pod" axis carries
data-parallel gradient reduction over the inter-pod links.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axes(mesh) -> tuple:
    """(data_axes, model_axis) for a mesh built by make_production_mesh."""
    names = mesh.axis_names
    model = "model" if "model" in names else None
    data = tuple(a for a in names if a in ("pod", "data"))
    return data, model


def chips(mesh) -> int:
    return mesh.devices.size
