"""Fault tolerance: restart supervision and straggler mitigation.

``run_elastic`` supervises a training function: on worker failure
(exception or simulated fault injection) it restarts from the latest
checkpoint — combined with the stateless data pipeline the restarted run
replays the identical batch stream, so recovery is bitwise deterministic
(integration-tested in tests/test_train_integration.py).

``StragglerMonitor`` implements the mitigation policy used at scale: track
a robust moving estimate of step latency; when a step exceeds
``threshold x median``, flag the step — the driver then (a) drops the
offending DP shard's gradient contribution and rescales by
``n/(n-kept)`` (gradient-rescale mode), or (b) fires a preemptive
checkpoint (checkpoint mode).  The decision logic is deterministic and
unit-tested; on real pods the signal comes from per-host heartbeats.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional


class SimulatedFailure(RuntimeError):
    """Injected node failure (tests / --fail-at)."""


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 2.5           # x median step time
    window: int = 32
    min_samples: int = 5
    _times: List[float] = dataclasses.field(default_factory=list)
    flagged: int = 0

    def observe(self, step_s: float) -> bool:
        """Record a step duration; True if this step is a straggler."""
        times = self._times
        is_straggler = False
        if len(times) >= self.min_samples:
            med = sorted(times)[len(times) // 2]
            is_straggler = step_s > self.threshold * med
        if not is_straggler:
            times.append(step_s)
            if len(times) > self.window:
                times.pop(0)
        else:
            self.flagged += 1
        return is_straggler

    def rescale_factor(self, total_shards: int, dropped: int) -> float:
        """Gradient rescale when dropping straggler DP shards."""
        kept = max(1, total_shards - dropped)
        return total_shards / kept


def run_elastic(train_fn: Callable[[Optional[int]], int],
                max_restarts: int = 3,
                on_restart: Optional[Callable[[int, BaseException], None]]
                = None) -> int:
    """Supervise ``train_fn(resume_step) -> final_step`` with restarts.

    ``train_fn`` must checkpoint internally and accept the step to resume
    from (None = fresh start / auto-detect).  Returns the final step.
    """
    restarts = 0
    resume: Optional[int] = None
    while True:
        try:
            return train_fn(resume)
        except SimulatedFailure as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart is not None:
                on_restart(restarts, e)
            resume = None   # train_fn re-reads LATEST checkpoint
