"""Sharding plans: parameter / optimizer / cache / batch PartitionSpecs.

The baseline plan is name-rule-driven 2D sharding: tensor-parallel over
"model" (attention heads, FFN columns, expert dim, vocab-free embedding
feature dim) and FSDP-style weight sharding over "data" (+"pod").  Every
axis assignment is divisibility-checked against the mesh and dropped when
it does not divide (e.g. 2-head KV caches on a 16-way model axis shard the
sequence dimension instead) — so every (arch x shape x mesh) cell lowers.

The Conduit-for-TPU scheduler (repro.distributed.scheduler) perturbs this
plan during the §Perf hillclimb.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import mesh_axes
from repro.models.config import ArchConfig

# column-parallel leaves (shard last dim over "model", -2 over data/FSDP)
_COL = {"wq", "wk", "wv", "w1", "w3", "w_uq", "w_uk", "w_uv", "w_q",
        "w_in", "w_bc", "w_dt", "w_gates", "w_if", "r_gates", "w_dkv",
        "w_dq", "router"}
# row-parallel leaves (shard -2 over "model", last over data/FSDP)
_ROW = {"wo", "w2", "w_out"}


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        return int(functools.reduce(
            lambda a, b: a * b, (mesh.shape[e] for e in entry), 1))
    return int(mesh.shape[entry])


def _fit(mesh, shape, spec_entries) -> P:
    """Drop axis assignments whose mesh extent does not divide the dim."""
    out = []
    for dim, entry in zip(shape, spec_entries):
        if entry is None:
            out.append(None)
            continue
        size = _axis_size(mesh, entry)
        out.append(entry if dim % size == 0 else None)
    return P(*out)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def _in_subtree(path, name: str) -> bool:
    return any(getattr(e, "key", None) == name for e in path)


def param_spec_for(path, shape, mesh, data: Tuple[str, ...],
                   model: Optional[str]) -> P:
    name = _leaf_name(path)
    nd = len(shape)
    dataspec = data if data else None
    if name in ("emb", "unemb"):
        if name == "emb":   # [V, D] -> feature dim over (data, model)
            combined = tuple(a for a in (data + ((model,) if model else ()))
                             if a)
            return _fit(mesh, shape, [None, combined or None])
        return _fit(mesh, shape, [tuple(data + ((model,) if model else ())) or
                                  None, None])
    if _in_subtree(path, "experts") and nd >= 3:
        # [L, E, D, F] / [L, E, F, D]: expert-parallel over model, FSDP over
        # the contraction dim.
        spec = [None] * nd
        spec[nd - 3] = model
        spec[nd - 2] = dataspec
        return _fit(mesh, shape, spec)
    if name in _COL and nd >= 2:
        spec = [None] * nd
        spec[nd - 1] = model
        spec[nd - 2] = dataspec
        return _fit(mesh, shape, spec)
    if name in _ROW and nd >= 2:
        spec = [None] * nd
        spec[nd - 1] = dataspec
        spec[nd - 2] = model
        return _fit(mesh, shape, spec)
    if name in ("conv_w", "a_log", "d_skip") and nd >= 1:
        spec = [None] * nd
        spec[nd - 1] = model
        return _fit(mesh, shape, spec)
    return P()   # norms and other small leaves: replicated


def param_specs(cfg: ArchConfig, params_shapes: Any, mesh) -> Any:
    data, model = mesh_axes(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec_for(path, leaf.shape, mesh, data,
                                          model),
        params_shapes)


def cache_spec_for(path, shape, mesh, data, model) -> P:
    name = _leaf_name(path)
    nd = len(shape)
    dataspec = data if data else None
    spec = [None] * nd
    if name in ("k", "v"):            # [L, B, S, Hkv, dh]
        spec[1] = dataspec
        spec[2] = model               # sequence-sharded cache
    elif name in ("latent", "k_rope"):  # [L, B, S, r]
        spec[1] = dataspec
        spec[2] = model
    elif name == "h" and nd == 4:     # mamba state [L, B, di, N]
        spec[1] = dataspec
        spec[2] = model
    elif name == "conv":              # [L, B, K-1, di]
        spec[1] = dataspec
        spec[3] = model
    elif name in ("c", "n", "m", "hid"):
        spec[1] = dataspec
    elif nd >= 2:
        spec[1] = dataspec
    return _fit(mesh, shape, spec)


def cache_specs(cfg: ArchConfig, cache_shapes: Any, mesh) -> Any:
    data, model = mesh_axes(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_spec_for(path, leaf.shape, mesh, data,
                                          model),
        cache_shapes)


def batch_spec(shape, mesh) -> P:
    """Token batches: batch dim over (pod, data)."""
    data, model = mesh_axes(mesh)
    spec = [data if data else None] + [None] * (len(shape) - 1)
    return _fit(mesh, shape, spec)


def embeds_spec(shape, mesh) -> P:
    data, model = mesh_axes(mesh)
    spec = [data if data else None] + [None] * (len(shape) - 2) + [model]
    return _fit(mesh, shape, spec)


def to_sds(tree_shapes: Any, tree_specs: Any, mesh) -> Any:
    """ShapeDtypeStructs with attached NamedShardings (no allocation)."""
    return jax.tree_util.tree_map(
        lambda leaf, spec: jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype, sharding=NamedSharding(mesh, spec)),
        tree_shapes, tree_specs)
