"""Step functions: train_step / prefill_step / serve_step per architecture.

These are the functions the dry-run lowers and the drivers execute.  The
train step is a full optimization step (loss, backward, AdamW with the
arch's schedule); the serve step is one decode iteration against the KV
cache / recurrent state.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ArchConfig
from repro.optim import adamw_update, make_schedule


def extra_inputs(cfg: ArchConfig, batch: int, seq: int) -> Dict[str, Any]:
    """Modality-frontend STUBS (per assignment): shapes of the precomputed
    frame/patch embeddings and auxiliary position streams."""
    extras: Dict[str, Any] = {}
    if cfg.frontend == "vision_patches":
        n_patch = 64                       # one low-res image per sequence
        extras["extra_embeds"] = jax.ShapeDtypeStruct(
            (batch, n_patch, cfg.d_model), jnp.bfloat16)
        extras["pos3"] = jax.ShapeDtypeStruct(
            (3, batch, seq + n_patch), jnp.int32)
    elif cfg.frontend == "audio_frames":
        n_frames = max(8, seq // 4)        # encoder frames per utterance
        extras["enc_feats"] = jax.ShapeDtypeStruct(
            (batch, n_frames, cfg.d_model), jnp.bfloat16)
    return extras


def build_train_step(cfg: ArchConfig, total_steps: int = 10_000,
                     base_lr: float = 3e-4,
                     microbatches: int = 1) -> Callable:
    """Full optimization step.  ``microbatches > 1`` accumulates gradients
    over batch slices (scan) — smaller activation peak and per-microbatch
    gradient reduction that XLA can overlap with the next microbatch's
    compute (the ConduitScheduler's `micro4` plan)."""
    schedule = make_schedule(cfg.schedule, base_lr, total_steps)

    def loss_of(p, batch):
        return M.lm_loss(
            cfg, p, batch["tokens"], batch["labels"],
            extra_embeds=batch.get("extra_embeds"),
            pos3=batch.get("pos3"),
            enc_feats=batch.get("enc_feats"))

    def train_step(params, opt_state, batch):
        step = opt_state.step
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            b = batch["tokens"].shape[0]
            assert b % microbatches == 0, (b, microbatches)
            mb = b // microbatches

            def slice_mb(i):
                return {k: jax.lax.dynamic_slice_in_dim(v, i * mb, mb, 0)
                        for k, v in batch.items()
                        if k in ("tokens", "labels", "extra_embeds")} | \
                    {k: v for k, v in batch.items()
                     if k not in ("tokens", "labels", "extra_embeds")}

            def body(carry, i):
                loss_acc, grads_acc = carry
                l, g = jax.value_and_grad(loss_of)(params, slice_mb(i))
                grads_acc = jax.tree_util.tree_map(
                    lambda a, b_: a + b_.astype(a.dtype), grads_acc, g)
                return (loss_acc + l, grads_acc), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.float32(0), zeros), jnp.arange(microbatches))
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
        lr = schedule(step)
        new_params, new_state, metrics = adamw_update(
            params, grads, opt_state, lr)
        metrics = dict(metrics, loss=loss, lr=lr)
        return new_params, new_state, metrics

    return train_step


def build_prefill_step(cfg: ArchConfig) -> Callable:
    def prefill_step(params, caches, batch):
        return M.prefill(
            cfg, params, batch["tokens"], caches,
            extra_embeds=batch.get("extra_embeds"),
            pos3=batch.get("pos3"),
            enc_feats=batch.get("enc_feats"))
    return prefill_step


def build_serve_step(cfg: ArchConfig) -> Callable:
    """One decode step: new token against a filled cache at ``index``."""
    def serve_step(params, caches, token, index, enc_out=None):
        return M.decode_step(cfg, params, token, index, caches,
                             enc_out=enc_out)
    return serve_step
