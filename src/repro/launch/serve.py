"""Batched serving driver: continuous-batching decode over a request queue.

Serves a (reduced-config) model: requests arrive with prompts of varying
length; the server left-pads to a batch, prefills once, then decodes the
whole batch step-by-step, retiring requests at EOS/max-tokens and backfilling
free slots from the queue.  Reports throughput and per-request latency
percentiles (the serving analogue of the paper's Fig. 8 tail-latency study).

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --requests 16 --batch 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.steps import build_prefill_step, build_serve_step
from repro.models import model as M
from repro.sim.stats import percentile


class Request:
    def __init__(self, rid: int, prompt: np.ndarray, max_new: int):
        self.rid = rid
        self.prompt = prompt
        self.max_new = max_new
        self.generated: List[int] = []
        self.t_arrive = time.time()
        self.t_done: Optional[float] = None


def serve(arch: str, n_requests: int, batch: int, prompt_len: int,
          max_new: int, reduced: bool = True, seed: int = 0) -> dict:
    cfg = configs.get(arch)
    if reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(seed)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    max_seq = prompt_len + max_new

    prefill_fn = jax.jit(build_prefill_step(cfg))
    serve_fn = jax.jit(build_serve_step(cfg), static_argnames=())

    queue = [Request(i, rng.integers(0, cfg.vocab, size=prompt_len,
                                     dtype=np.int32), max_new)
             for i in range(n_requests)]
    done: List[Request] = []
    t0 = time.time()
    total_tokens = 0

    while queue:
        active = [queue.pop(0) for _ in range(min(batch, len(queue)))]
        tokens = jnp.asarray(np.stack([r.prompt for r in active]))
        caches = M.init_cache(cfg, len(active), max_seq)
        logits, caches = prefill_fn(params, caches, {"tokens": tokens})
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        for step in range(max_new):
            for r, tok in zip(active, np.asarray(nxt)):
                if r.t_done is None:
                    r.generated.append(int(tok))
                    total_tokens += 1
                    if len(r.generated) >= r.max_new:
                        r.t_done = time.time()
            if all(r.t_done is not None for r in active):
                break
            logits, caches = serve_fn(params, caches, nxt,
                                      jnp.int32(prompt_len + step))
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for r in active:
            if r.t_done is None:
                r.t_done = time.time()
            done.append(r)

    wall = time.time() - t0
    lat = [(r.t_done - r.t_arrive) * 1e3 for r in done]
    out = {
        "requests": len(done),
        "tokens": total_tokens,
        "tokens_per_s": total_tokens / wall,
        "wall_s": wall,
        "latency_ms_p50": percentile(lat, 50),
        "latency_ms_p99": percentile(lat, 99),
    }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=configs.ARCHS)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    res = serve(args.arch, args.requests, args.batch, args.prompt_len,
                args.max_new, reduced=not args.full)
    for k, v in res.items():
        print(f"  {k}: {v:.2f}" if isinstance(v, float) else f"  {k}: {v}")


if __name__ == "__main__":
    main()
