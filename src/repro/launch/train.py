"""Training driver.

CPU-scale end-to-end training of any ``--arch`` (reduced config by default)
with checkpoint/restart, deterministic data, straggler monitoring, and
optional fault injection; on TPU pods the same driver runs the full config
under the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --steps 100 --batch 8 --seq 64 --ckpt-dir /tmp/ck
  # crash at step 37 and restart from the last checkpoint:
  ... --fail-at 37 --max-restarts 1
"""
from __future__ import annotations

import argparse
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM
from repro.launch.elastic import (SimulatedFailure, StragglerMonitor,
                                  run_elastic)
from repro.launch.steps import build_train_step
from repro.models import model as M
from repro.optim.adamw import adamw_init


def make_state(cfg, seed: int):
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    return {"params": params, "opt": adamw_init(params)}


def train(arch: str, steps: int, batch: int, seq: int,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 20,
          reduced: bool = True, fail_at: Optional[int] = None,
          seed: int = 0, log_every: int = 10,
          resume: bool = True, base_lr: float = 1e-3) -> dict:
    cfg = configs.get(arch)
    if reduced:
        cfg = cfg.reduced()
    data = SyntheticLM(cfg.vocab, seq, batch, seed=seed)
    step_fn = jax.jit(build_train_step(cfg, total_steps=steps,
                                       base_lr=base_lr))
    state = make_state(cfg, seed)
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start = 0
    if mgr is not None and resume and mgr.latest_step() is not None:
        state, manifest = mgr.restore(state)
        start = manifest["step"]
        print(f"[train] resumed from checkpoint step {start}")

    mon = StragglerMonitor()
    losses = []
    for step in range(start, steps):
        if fail_at is not None and step == fail_at:
            raise SimulatedFailure(f"injected node failure at step {step}")
        t0 = time.time()
        np_batch = data.batch(step)
        jbatch = {k: jnp.asarray(v) for k, v in np_batch.items()}
        params, opt, metrics = step_fn(state["params"], state["opt"], jbatch)
        state = {"params": params, "opt": opt}
        dt = time.time() - t0
        straggler = mon.observe(dt)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"{dt*1e3:7.1f}ms{'  STRAGGLER' if straggler else ''}")
        if mgr is not None and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, state, extra={"arch": arch, "loss": loss})
    if mgr is not None:
        mgr.save(steps, state, extra={"arch": arch}, blocking=True)
        mgr.wait()
    return {"final_loss": losses[-1] if losses else None,
            "first_loss": losses[0] if losses else None,
            "stragglers": mon.flagged, "state": state,
            "losses": losses}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=configs.ARCHS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-reduced) config")
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    attempted = {"n": 0}

    def once(_resume_step):
        # fail only on the first attempt so the restart proves recovery
        fail = args.fail_at if attempted["n"] == 0 else None
        attempted["n"] += 1
        res = train(args.arch, args.steps, args.batch, args.seq,
                    ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                    reduced=not args.full, fail_at=fail, base_lr=args.lr)
        print(f"[train] done: first_loss={res['first_loss']:.4f} "
              f"final_loss={res['final_loss']:.4f} "
              f"stragglers={res['stragglers']}")
        return args.steps

    run_elastic(once, max_restarts=args.max_restarts,
                on_restart=lambda n, e: print(f"[elastic] restart #{n}: {e}"))


if __name__ == "__main__":
    main()
