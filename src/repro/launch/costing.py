"""Trip-count-corrected cost extraction from compiled dry-run artifacts.

XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
count (verified empirically), so a scan-over-layers model under-reports
FLOPs/bytes/collective traffic by ~the layer count.  We correct by
compiling a per-segment *probe* — one layer body with the identical
sharded shapes (forward for serving cells; forward+backward(+remat
recompute) for training cells) — and adding ``(count-1) x probe_cost`` to
the aggregate numbers.

All reported numbers are PER-DEVICE (the compiled module is the per-device
program), matching the per-chip roofline terms.
"""
from __future__ import annotations

import functools
import re
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as SH
from repro.launch.mesh import mesh_axes
from repro.models import model as M
from repro.models.config import ArchConfig

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(r"([a-z][a-z0-9\-]*)\(")


def _result_bytes(line: str) -> int:
    """Bytes of an HLO op's result — the type(s) between '=' and the op."""
    parts = line.split(" = ", 1)
    if len(parts) != 2:
        return 0
    rhs = parts[1]
    m = _OP_RE.search(rhs)
    head = rhs[:m.start()] if m else rhs
    total = 0
    for sm in _SHAPE_RE.finditer(head):
        dt, dims = sm.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device result bytes of every collective op in post-SPMD HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    ops = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        m = _OP_RE.search(s.split(" = ", 1)[1]) if " = " in s else None
        if not m:
            continue
        op = m.group(1)
        for kind in _COLLECTIVES:
            if op == kind or op.startswith(kind + "-"):
                out[kind] += _result_bytes(s)
                ops += 1
                break
    out["ops"] = ops
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def costs_of(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    text = compiled.as_text()
    coll = collective_bytes(text)
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "collectives": coll}


def _one_layer_params_sds(cfg: ArchConfig, kind: str, mesh):
    data, model = mesh_axes(mesh)
    shapes = jax.eval_shape(
        lambda k: M._block_init(kind, k, cfg, jnp.dtype(cfg.dtype)),
        jax.random.PRNGKey(0))
    specs = jax.tree_util.tree_map_with_path(
        lambda path, leaf: SH.param_spec_for(path, leaf.shape, mesh, data,
                                             model),
        shapes)
    return SH.to_sds(shapes, specs, mesh)


def _x_sds(cfg: ArchConfig, batch: int, seq: int, mesh):
    data, model = mesh_axes(mesh)
    shape = (batch, seq, cfg.d_model)
    spec = SH._fit(mesh, shape, [data or None, None, model])
    return jax.ShapeDtypeStruct(shape, jnp.dtype(cfg.dtype),
                                sharding=NamedSharding(mesh, spec))


def _cache_sds(cfg: ArchConfig, kind: str, batch: int, seq: int, mesh):
    shapes = jax.eval_shape(
        functools.partial(M._block_cache, kind, cfg, batch, seq))
    data, model = mesh_axes(mesh)
    # cache_spec_for expects a leading layer dim; strip it back off
    specs = jax.tree_util.tree_map_with_path(
        lambda path, leaf: P(*tuple(SH.cache_spec_for(
            path, (1,) + leaf.shape, mesh, data, model))[1:]),
        shapes)
    return SH.to_sds(shapes, specs, mesh)


def probe_segment(cfg: ArchConfig, kind: str, step_kind: str,
                  batch: int, seq: int, mesh) -> Dict[str, float]:
    """Compile one layer body with cell-identical sharded shapes and return
    its per-device cost record (plus 'fwd' sub-record for train remat)."""
    body_kind = "attn" if kind == "sattn" else kind
    p_sds = _one_layer_params_sds(cfg, body_kind, mesh)
    positions = jax.ShapeDtypeStruct((batch, seq), jnp.int32,
                                     sharding=NamedSharding(
                                         mesh, SH.batch_spec((batch, seq),
                                                             mesh)))

    if step_kind == "train":
        x_sds = _x_sds(cfg, batch, seq, mesh)

        def fwd(p_l, x, pos):
            out, _ = M.block_apply(body_kind, cfg, p_l, x, pos)
            return out

        def fwdbwd(p_l, x, pos):
            def g(p_l, x):
                return fwd(p_l, x, pos).astype(jnp.float32).sum()
            return jax.grad(g, argnums=(0, 1))(p_l, x)

        with jax.set_mesh(mesh):
            c_fwd = jax.jit(fwd).lower(p_sds, x_sds, positions).compile()
            c_fb = jax.jit(fwdbwd).lower(p_sds, x_sds, positions).compile()
        fwd_cost = costs_of(c_fwd)
        fb = costs_of(c_fb)
        if cfg.remat:
            # scan+checkpoint executes fwd once and (fwd + bwd) at grad time
            for k in ("flops", "bytes"):
                fb[k] += fwd_cost[k]
            for k in fb["collectives"]:
                fb["collectives"][k] += fwd_cost["collectives"][k]
        return fb

    # serving: decode (seq=1 against cache) or prefill (cache fill)
    cache_sds = _cache_sds(cfg, body_kind, batch, seq, mesh)
    qlen = 1 if step_kind == "decode" else seq
    x_sds = _x_sds(cfg, batch, qlen, mesh)
    pos_q = jax.ShapeDtypeStruct((batch, qlen), jnp.int32,
                                 sharding=NamedSharding(
                                     mesh, SH.batch_spec((batch, qlen),
                                                         mesh)))

    def serve_body(p_l, x, pos, cache):
        cache_in = M._with_index(cache, jnp.int32(0))
        out, nc = M.block_apply(body_kind, cfg, p_l, x, pos, cache_in)
        return out, M._strip_index(nc)

    with jax.set_mesh(mesh):
        c = jax.jit(serve_body).lower(p_sds, x_sds, pos_q,
                                      cache_sds).compile()
    return costs_of(c)


def corrected_costs(cfg: ArchConfig, step_kind: str, batch: int, seq: int,
                    mesh, agg: Dict[str, float]) -> Dict[str, float]:
    """agg (whole-cell compile, bodies counted once) + (count-1) x probes."""
    out = {"flops": agg["flops"], "bytes": agg["bytes"],
           "collectives": dict(agg["collectives"])}
    probes = {}
    for kind, count in M.segments_of(cfg):
        reps = count - 1
        if kind == "sattn":
            # shared attn blocks are unrolled in the HLO already
            continue
        if reps <= 0:
            continue
        if kind not in probes:
            probes[kind] = probe_segment(cfg, kind, step_kind, batch, seq,
                                         mesh)
        pr = probes[kind]
        out["flops"] += reps * pr["flops"]
        out["bytes"] += reps * pr["bytes"]
        for k in pr["collectives"]:
            out["collectives"][k] = (out["collectives"].get(k, 0)
                                     + reps * pr["collectives"][k])
    if cfg.enc_layers > 1 and step_kind in ("train", "prefill"):
        # encoder scan: approximate with the decoder block probe family
        pass
    return out
