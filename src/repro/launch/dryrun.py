import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes and extract the roofline terms.

The two lines above MUST run before any other import (jax locks the device
count at first init); do not set this flag globally — smoke tests and
benches see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json
"""

import argparse        # noqa: E402
import json            # noqa: E402
import re              # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402

import jax             # noqa: E402
import numpy as np     # noqa: E402

from repro import configs                                  # noqa: E402
from repro.configs.shapes import SHAPES, SHAPE_ORDER, applicable  # noqa: E402
from repro.hw.tpu_spec import TPU_V5E                      # noqa: E402
from repro.launch import costing                           # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_axes  # noqa: E402
from repro.launch.specs import input_specs                 # noqa: E402
from repro.models import layers as L                       # noqa: E402

def run_cell(arch: str, shape: str, multi_pod: bool,
             save_hlo: str | None = None) -> dict:
    """Lower+compile one cell; returns the roofline record."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    data, model = mesh_axes(mesh)
    L.set_mesh_axes(data, model)
    cfg = configs.get(arch)
    t0 = time.time()
    fn, args, kind = input_specs(arch, shape, mesh)
    # buffer donation (perf iteration D2/T1): caches update in place for
    # serving; params/optimizer state update in place for training — without
    # donation XLA copies the full buffers every step.
    donate = {"train": (0, 1), "prefill": (1,), "decode": (1,)}[kind]
    with jax.set_mesh(mesh):
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    rec = {
        "arch": arch, "shape": shape, "kind": kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(mesh.devices.size),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                rec[attr] = int(v)
        rec["per_device_bytes"] = (rec.get("argument_size_in_bytes", 0)
                                   + rec.get("temp_size_in_bytes", 0)
                                   + rec.get("output_size_in_bytes", 0))
    except Exception as e:  # pragma: no cover
        rec["memory_analysis_error"] = str(e)

    agg = costing.costs_of(compiled)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(compiled.as_text())
    spec = SHAPES[shape]
    t1 = time.time()
    # trip-count correction: scan bodies are counted once by cost_analysis
    try:
        corr = costing.corrected_costs(
            cfg, kind, spec.global_batch,
            spec.seq_len if kind != "decode" else spec.seq_len, mesh, agg)
        rec["probe_s"] = round(time.time() - t1, 1)
    except Exception as e:  # pragma: no cover
        rec["probe_error"] = str(e)
        corr = agg
    rec["flops"] = corr["flops"]                    # per device
    rec["hlo_bytes"] = corr["bytes"]                # per device
    rec["collectives"] = corr["collectives"]        # per device
    rec["raw_agg"] = {"flops": agg["flops"], "bytes": agg["bytes"],
                      "collective_bytes": agg["collectives"]["total"]}

    # three-term per-chip roofline (§Roofline): cost numbers are already
    # per-device, so chips=1 in the divisor.
    terms = TPU_V5E.roofline_terms(rec["flops"], rec["hlo_bytes"],
                                   rec["collectives"]["total"], 1)
    rec["roofline"] = terms
    # MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); decode D=tokens=B
    if kind == "train":
        tokens = spec.global_batch * spec.seq_len
        model_flops = 6 * cfg.active_param_count() * tokens
    elif kind == "prefill":
        tokens = spec.global_batch * spec.seq_len
        model_flops = 2 * cfg.active_param_count() * tokens
    else:
        tokens = spec.global_batch
        model_flops = 2 * cfg.active_param_count() * tokens
    rec["model_flops"] = model_flops                # global
    total_hlo_flops = rec["flops"] * rec["chips"]
    rec["useful_flop_ratio"] = (model_flops / total_hlo_flops
                                if total_hlo_flops else None)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--hlo-dir", default=None)
    args = ap.parse_args()

    archs = configs.ARCHS if args.arch == "all" or args.all else [args.arch]
    shapes = list(SHAPE_ORDER) if args.shape == "all" or args.all \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    records = []
    for arch in archs:
        cfg = configs.get(arch)
        for shape in shapes:
            ok, reason = applicable(cfg, shape)
            if not ok:
                records.append({"arch": arch, "shape": shape,
                                "skipped": reason})
                print(f"SKIP  {arch:22s} {shape:12s} {reason}")
                continue
            for multi in meshes:
                tag = "2x16x16" if multi else "16x16"
                try:
                    hlo = None
                    if args.hlo_dir:
                        os.makedirs(args.hlo_dir, exist_ok=True)
                        hlo = os.path.join(args.hlo_dir,
                                           f"{arch}_{shape}_{tag}.hlo")
                    rec = run_cell(arch, shape, multi_pod=multi,
                                   save_hlo=hlo)
                    records.append(rec)
                    r = rec["roofline"]
                    print(f"OK    {arch:22s} {shape:12s} {tag:8s} "
                          f"flops={rec['flops']:.3e} "
                          f"coll={rec['collectives']['total']:.3e}B "
                          f"bound={r['dominant']:10s} "
                          f"[lower {rec['lower_s']}s compile "
                          f"{rec['compile_s']}s]")
                except Exception as e:
                    records.append({"arch": arch, "shape": shape,
                                    "mesh": tag, "error": str(e)})
                    print(f"FAIL  {arch:22s} {shape:12s} {tag:8s} {e}")
                    traceback.print_exc()
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {args.out} ({len(records)} records)")


if __name__ == "__main__":
    main()
