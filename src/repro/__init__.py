"""Conduit reproduction: programmer-transparent NDP offloading (the paper's
framework, §4) + the same cost-function insight as a multi-pod JAX
training/serving stack.

Public API:
    repro.core.vectorize      compile-time pass: JAX fn -> vector IR
    repro.sim.simulate        event-driven execution under any policy
    repro.configs.get         the 10 assigned architecture configs
    repro.launch.*            mesh / dryrun / train / serve drivers
"""
__version__ = "1.0.0"
