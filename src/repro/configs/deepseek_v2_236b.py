"""deepseek-v2-236b [moe]: 60L d_model=5120 128H (GQA kv=128) d_ff=1536
vocab=102400, MoE 160 routed experts top-6 + 2 shared, MLA kv_lora=512.
[arXiv:2405.04434; hf]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=1536, vocab=102400, d_head=128,
    moe=True, n_experts=160, experts_per_tok=6, n_shared_experts=2,
    moe_d_ff=1536,
    mla=True, kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
    tie_embeddings=False,
    source="arXiv:2405.04434; hf",
)
