"""Assigned architecture configurations (``--arch <id>``).

One module per architecture with the exact published config; ``get(name)``
returns the ArchConfig, ``ARCHS`` lists all ids.  Input-shape sets are in
:mod:`repro.configs.shapes`.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ArchConfig

ARCHS: List[str] = [
    "minicpm-2b", "tinyllama-1.1b", "qwen3-4b", "stablelm-1.6b",
    "dbrx-132b", "deepseek-v2-236b", "zamba2-1.2b", "seamless-m4t-medium",
    "qwen2-vl-2b", "xlstm-125m",
]

# the paper's own model (§5.4) — selectable but not in the assigned pool
PAPER_ARCHS = ["llama2-7b"]

_MODULES = {
    "llama2-7b": "llama2_7b",
    "minicpm-2b": "minicpm_2b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "qwen3-4b": "qwen3_4b",
    "stablelm-1.6b": "stablelm_1_6b",
    "dbrx-132b": "dbrx_132b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "zamba2-1.2b": "zamba2_1_2b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "xlstm-125m": "xlstm_125m",
}


def get(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {name: get(name) for name in ARCHS}
