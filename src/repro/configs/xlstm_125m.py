"""xlstm-125m [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304 — alternating
sLSTM + mLSTM blocks (1:1), no FFN.  [arXiv:2405.04517; unverified]"""
from repro.models.config import ArchConfig

_pattern = tuple("mlstm" if i % 2 == 0 else "slstm" for i in range(12))

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    block_pattern=_pattern,
    tie_embeddings=True,
    source="arXiv:2405.04517; unverified",
)
