"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention block
applied every 6 layers.  [arXiv:2411.15242; hf]"""
from repro.models.config import ArchConfig

_pattern = []
for i in range(38):
    _pattern.append("mamba")
    if (i + 1) % 6 == 0:
        _pattern.append("sattn")       # shared attention block (re-used params)

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000,
    block_pattern=tuple(_pattern),
    ssm_state=64, ssm_expand=2, conv_kernel=4, shared_attn_every=6,
    tie_embeddings=True,
    source="arXiv:2411.15242; hf",
)
