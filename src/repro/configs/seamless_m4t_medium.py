"""seamless-m4t-medium [audio]: 12L d_model=1024 16H (GQA kv=16) d_ff=4096
vocab=256206 — encoder-decoder; the speech frontend is a STUB
(input_specs provides precomputed frame embeddings).  [arXiv:2308.11596; hf]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206,
    enc_layers=12, frontend="audio_frames",
    block_pattern=tuple(["xdec"] * 12),
    tie_embeddings=True,
    source="arXiv:2308.11596; hf",
)
