"""llama2-7b — the paper's own evaluated model (§5.4: INT8 LLaMA2-7B
inference and training via llama2.c [308]).  Not part of the assigned
10-arch pool; selectable for dry-runs and the simulator workloads."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama2-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab=32000,
    tie_embeddings=False,
    source="arXiv:2307.09288; github.com/karpathy/llama2.c",
)
