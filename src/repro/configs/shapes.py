"""Input-shape sets assigned to the LM-family architectures (40 cells).

  train_4k     seq_len=4096   global_batch=256   (training)
  prefill_32k  seq_len=32768  global_batch=32    (inference prefill)
  decode_32k   seq_len=32768  global_batch=128   (decode: 1 new token / KV)
  long_500k    seq_len=524288 global_batch=1     (long-context decode;
                                                  sub-quadratic archs only)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def applicable(cfg: ArchConfig, shape: str) -> Tuple[bool, str]:
    """Whether (arch x shape) is a runnable cell; else (False, reason).

    long_500k requires sub-quadratic attention — pure full-attention archs
    skip it (recorded in EXPERIMENTS.md §Dry-run), SSM/hybrid archs run it.
    """
    spec = SHAPES[shape]
    if spec.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("skip: pure full-attention architecture — 524k-token "
                       "decode shape is assigned to sub-quadratic archs only")
    return True, ""
