"""INT8 quantized matmul with INT32 accumulation (MXU-tiled Pallas kernel).

The paper quantizes every workload to INT8 (§5.4); the LLM workloads'
dominant compute is INT8 GEMM.  The kernel tiles (M, N, K) with MXU-aligned
128-multiples blocks; the K grid axis accumulates into the output tile
(revisiting semantics), so one output block stays resident in VMEM across
all K steps — the standard TPU matmul schedule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, out_ref):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    out_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def int8_matmul(a: jnp.ndarray, b: jnp.ndarray,
                block_m: int = 128, block_n: int = 128, block_k: int = 128,
                interpret: bool = True) -> jnp.ndarray:
    """``a[int8, M,K] @ b[int8, K,N] -> int32[M,N]``, MXU-aligned tiling."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, \
        f"shape ({m},{k})x({k},{n}) not tileable by ({block_m},{block_n},{block_k})"
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(a, b)
