"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each ``ref_*`` implements the mathematical specification with plain jnp ops;
tests sweep shapes/dtypes and assert the Pallas kernels (interpret mode on
CPU, compiled on TPU) match bit-exactly for integer kernels and to fp
tolerance for the attention kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def ref_mws(stack: jnp.ndarray, op: str) -> jnp.ndarray:
    """Multi-wordline-sensing bulk bitwise reduce over operand axis 0.

    Flash-Cosmos semantics: one simultaneous multi-wordline sense computes
    the AND (wired-AND of series-connected cells) / OR (across blocks) of up
    to 48 stacked pages in a single array operation.
    """
    if op == "and":
        return jax.lax.reduce(stack, jnp.array(-1, stack.dtype),
                              jnp.bitwise_and, (0,))
    if op == "or":
        return jax.lax.reduce(stack, jnp.array(0, stack.dtype),
                              jnp.bitwise_or, (0,))
    if op == "xor":
        return jax.lax.reduce(stack, jnp.array(0, stack.dtype),
                              jnp.bitwise_xor, (0,))
    if op == "nand":
        return ~ref_mws(stack, "and")
    if op == "nor":
        return ~ref_mws(stack, "or")
    raise ValueError(op)


def ref_bitserial_add(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Bit-serial ripple add (SIMDRAM MAJ/XOR circuit) == integer add."""
    return a + b


def ref_bitserial_mul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Bit-serial shift-add multiply == integer multiply (wrapping)."""
    return a * b


def ref_shift_add_mul(a: jnp.ndarray, b: jnp.ndarray,
                      bits: int = 8) -> jnp.ndarray:
    """Ares-Flash shift-and-add over the low ``bits`` of b (unsigned)."""
    mask = (1 << bits) - 1
    return a * (b & mask)


def ref_int8_matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """INT8 x INT8 -> INT32 matmul (the quantized-workload GEMM, §5.4)."""
    return jnp.dot(a.astype(jnp.int32), b.astype(jnp.int32),
                   preferred_element_type=jnp.int32)


def ref_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, scale: float | None = None
                  ) -> jnp.ndarray:
    """Standard softmax attention, [heads, seq, dh] layout."""
    h, sq, d = q.shape
    sk = k.shape[1]
    if scale is None:
        scale = 1.0 / np.sqrt(d)
    logits = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = np.tril(np.ones((sq, sk), bool), k=sk - sq)
        logits = jnp.where(mask[None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


def ref_search(stack: jnp.ndarray, query: jnp.ndarray) -> jnp.ndarray:
    """Exact-match search oracle: record r of page p matches iff all its
    words equal the query words."""
    rows, words = stack.shape
    wpr = query.shape[0]
    recv = stack.reshape(rows, words // wpr, wpr)
    return jnp.all(recv == query[None, None, :], axis=-1)
