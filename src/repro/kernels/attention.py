"""Tiled attention with online softmax (FlashAttention-style Pallas kernel).

Used by the LM-family architectures' prefill path.  Grid = (heads,
q-blocks); each invocation holds one q tile in VMEM and streams k/v tiles
with the running (max, normalizer, accumulator) online-softmax state — no
[seq, seq] score materialization, which is what makes 32k-token prefill
VMEM-feasible on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, out_ref, *, block_q: int,
                 block_k: int, seq_k: int, causal: bool, scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # [bq, d]
    bq, d = q.shape
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, d), jnp.float32)

    def body(kb, carry):
        m, l, acc = carry
        start = kb * block_k
        k = k_ref[0, pl.ds(start, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.ds(start, block_k), :].astype(jnp.float32)
        s = q @ k.T                                  # [bq, bk]
        if causal:
            k_pos = start + jax.lax.broadcasted_iota(
                jnp.int32, (bq, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    nkb = seq_k // block_k
    if causal:
        # skip fully-masked k blocks past the diagonal
        nkb_eff = jnp.minimum(nkb, (qi + 1) * block_q // block_k
                              + (1 if block_q % block_k or True else 0))
        nkb_eff = jnp.minimum(nkb, ((qi + 1) * block_q + block_k - 1)
                              // block_k)
    else:
        nkb_eff = nkb
    m, l, acc = jax.lax.fori_loop(0, nkb_eff, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    out_ref[0] = out.astype(out_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """Attention over ``q/k/v [heads, seq, dh]`` with online softmax."""
    h, sq, d = q.shape
    _, sk, _ = k.shape
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    if scale is None:
        scale = 1.0 / np.sqrt(d)
    grid = (h, sq // block_q)
    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, seq_k=sk,
        causal=causal, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda hh, qq: (hh, qq, 0)),
            pl.BlockSpec((1, sk, d), lambda hh, qq: (hh, 0, 0)),
            pl.BlockSpec((1, sk, d), lambda hh, qq: (hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda hh, qq: (hh, qq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
