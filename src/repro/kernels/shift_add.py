"""Ares-Flash latch-based shift-and-add multiply as a Pallas kernel (IFP).

Ares-Flash extends the flash plane's page-buffer latches (S/A/B/C) with
transmission gates so a page can be ANDed with a broadcast bit, shifted,
and accumulated — integer multiply as W latch-level shift-add rounds.

TPU adaptation: each "latch round" is one VPU pass over the VMEM tile; the
broadcast multiplier bit is extracted per element (the in-flash version
broadcasts one operand bit-plane per round).  Only the low ``bits`` of the
multiplier participate, exactly like the latch datapath width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _shift_add_kernel(a_ref, b_ref, out_ref, *, bits: int):
    a = a_ref[...]
    b = b_ref[...]
    acc = jnp.zeros_like(a)

    def round_(i, acc):
        bit = (b >> i) & 1                      # latch-broadcast bit plane
        return acc + jnp.where(bit == 1, a << i, 0)

    out_ref[...] = jax.lax.fori_loop(0, bits, round_, acc)


def shift_add_mul(a: jnp.ndarray, b: jnp.ndarray, bits: int = 8,
                  block_rows: int = 8, block_cols: int = 512,
                  interpret: bool = True) -> jnp.ndarray:
    """a * (b & ((1<<bits)-1)) via the Ares-Flash shift-and-add datapath."""
    rows, cols = a.shape
    block_rows = min(block_rows, rows)
    block_cols = min(block_cols, cols)
    assert rows % block_rows == 0 and cols % block_cols == 0
    grid = (rows // block_rows, cols // block_cols)
    spec = pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_shift_add_kernel, bits=bits),
        grid=grid, in_specs=[spec, spec], out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(a, b)
