"""Jit'd public wrappers for the Pallas kernels (shape checks + padding).

``interpret`` defaults to True on CPU backends (this container) and False
on real TPU — resolved once at import.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import attention as _attention
from repro.kernels import bitserial as _bitserial
from repro.kernels import int8_matmul as _int8_matmul
from repro.kernels import mws as _mws
from repro.kernels import search as _search
from repro.kernels import shift_add as _shift_add

INTERPRET = jax.default_backend() != "tpu"


def _pad_to(x, mult_rows, mult_cols):
    r, c = x.shape[-2:]
    pr = (-r) % mult_rows
    pc = (-c) % mult_cols
    if pr or pc:
        pad = [(0, 0)] * (x.ndim - 2) + [(0, pr), (0, pc)]
        x = jnp.pad(x, pad)
    return x, r, c


@functools.partial(jax.jit, static_argnames=("op",))
def mws_bitwise(stack: jnp.ndarray, op: str = "and") -> jnp.ndarray:
    """Bulk bitwise reduce of stacked pages (Flash-Cosmos MWS)."""
    assert stack.ndim == 3, "expected [n_ops, rows, cols]"
    assert jnp.issubdtype(stack.dtype, jnp.integer)
    padded, r, c = _pad_to(stack, 8, 128)
    out = _mws.mws_bitwise(padded, op=op, interpret=INTERPRET)
    return out[:r, :c]


@jax.jit
def bitserial_add(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    assert a.shape == b.shape and a.dtype == b.dtype
    pa, r, c = _pad_to(a, 8, 128)
    pb, _, _ = _pad_to(b, 8, 128)
    return _bitserial.bitserial_add(pa, pb, interpret=INTERPRET)[:r, :c]


@jax.jit
def bitserial_mul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    assert a.shape == b.shape and a.dtype == b.dtype
    pa, r, c = _pad_to(a, 8, 128)
    pb, _, _ = _pad_to(b, 8, 128)
    return _bitserial.bitserial_mul(pa, pb, interpret=INTERPRET)[:r, :c]


@functools.partial(jax.jit, static_argnames=("bits",))
def shift_add_mul(a: jnp.ndarray, b: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    assert a.shape == b.shape and a.dtype == b.dtype
    pa, r, c = _pad_to(a, 8, 128)
    pb, _, _ = _pad_to(b, 8, 128)
    return _shift_add.shift_add_mul(pa, pb, bits=bits,
                                    interpret=INTERPRET)[:r, :c]


@jax.jit
def int8_matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    assert a.dtype == jnp.int8 and b.dtype == jnp.int8
    m, k = a.shape
    k2, n = b.shape
    bm = min(128, m) if m % 128 else 128
    bn = min(128, n) if n % 128 else 128
    bk = min(128, k) if k % 128 else 128
    # fall back to largest dividing power-of-two block
    def blk(dim, pref):
        b = min(pref, dim)
        while dim % b:
            b //= 2
        return max(1, b)
    return _int8_matmul.int8_matmul(
        a, b, block_m=blk(m, 128), block_n=blk(n, 128), block_k=blk(k, 128),
        interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("causal",))
def flash_attention(q, k, v, causal: bool = True) -> jnp.ndarray:
    def blk(dim, pref):
        b = min(pref, dim)
        while dim % b:
            b //= 2
        return max(1, b)
    return _attention.flash_attention(
        q, k, v, causal=causal,
        block_q=blk(q.shape[1], 128), block_k=blk(k.shape[1], 128),
        interpret=INTERPRET)


@jax.jit
def search_pages(stack: jnp.ndarray, query: jnp.ndarray) -> jnp.ndarray:
    """In-flash exact-match search (§7 extensibility kernel)."""
    assert stack.ndim == 2 and query.ndim == 1
    padded, r, c = _pad_to(stack, 8, stack.shape[1])
    return _search.search_pages(padded, query, interpret=INTERPRET)[:r]
