"""Pallas TPU kernels for the compute hot-spots the paper's resources model.

Each kernel: <name>.py (pl.pallas_call + explicit BlockSpec VMEM tiling),
jit'd wrapper in ops.py, pure-jnp oracle in ref.py.  Validated in
interpret mode on CPU; compiled on TPU.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
