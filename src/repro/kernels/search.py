"""In-flash exact-match search as a Pallas kernel (paper §7 extensibility).

The paper names search as a natural Conduit extension (Search-in-Memory /
TCAM-SSD class works): matching a query word against every stored word of a
page reduces to XNOR(query, word) followed by an all-bits AND — both MWS
primitives.  TPU adaptation: the page stack sits in a VMEM tile; the
broadcast query XNORs against every lane and a full-width popcount-equality
check yields the match bitmap, all in one pass (no HBM round-trips between
the XNOR and the reduction, mirroring in-array match lines).

``search_pages(stack[n_pages, words], query[words_per_rec]) -> match
bitmap [n_pages, records]`` where each record is ``words_per_rec``
consecutive int32 words.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _search_kernel(stack_ref, query_ref, out_ref, *, words_per_rec: int):
    page = stack_ref[...]                       # [rows, words]
    q = query_ref[...]                          # [1, words_per_rec]
    rows, words = page.shape
    recs = words // words_per_rec
    recv = page.reshape(rows, recs, words_per_rec)
    xnor = ~(recv ^ q[0][None, None, :])        # all-ones where bits equal
    eq_word = xnor == -1                        # word equality
    out_ref[...] = jnp.all(eq_word, axis=-1)    # record match bitmap


def search_pages(stack: jnp.ndarray, query: jnp.ndarray,
                 block_rows: int = 8, interpret: bool = True) -> jnp.ndarray:
    """Exact-match search of ``query`` against record-structured pages."""
    rows, words = stack.shape
    (wpr,) = query.shape
    assert words % wpr == 0, (words, wpr)
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_search_kernel, words_per_rec=wpr),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, words), lambda i: (i, 0)),
            pl.BlockSpec((1, wpr), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, words // wpr), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, words // wpr), jnp.bool_),
        interpret=interpret,
    )(stack, query[None, :])
