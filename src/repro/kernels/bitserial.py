"""SIMDRAM/MIMDRAM bit-serial arithmetic as a Pallas kernel (PuD-SSD model).

TPU adaptation (DESIGN.md §4a): Ambit's triple-row-activation MAJ/NOT over
vertically-laid-out bit-planes becomes vectorized bitwise logic on the VPU
over int tiles in VMEM.  The ripple-carry adder and shift-add multiplier
below use ONLY the PuD primitive set {AND, OR, XOR, NOT, shift} — the same
gate-level circuits SIMDRAM synthesizes — so the kernel is a functional
model of the in-DRAM computation, executed tile-by-tile in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _add_kernel(a_ref, b_ref, out_ref, *, bits: int):
    """Ripple-carry add via MAJ(=carry)/XOR(=sum) bit-plane circuit."""
    a = a_ref[...]
    b = b_ref[...]

    def body(_, carry):
        a, b = carry
        s = a ^ b                    # partial sum      (XOR row-op)
        c = (a & b) << 1             # carry, shifted   (MAJ row-op + shift)
        return s, c

    s, c = jax.lax.fori_loop(0, bits, body, (a, b))
    out_ref[...] = s | c             # carry fully propagated after W steps


def _mul_kernel(a_ref, b_ref, out_ref, *, bits: int):
    """Shift-add multiply: W partial products, each AND+add (bit-serial)."""
    a = a_ref[...]
    b = b_ref[...]
    acc = jnp.zeros_like(a)

    def body(i, acc):
        bit = (b >> i) & 1
        pp = jnp.where(bit == 1, a << i, 0)   # predicated partial product
        # bit-serial add of pp into acc (same MAJ/XOR circuit)
        def add_body(_, carry):
            x, y = carry
            return x ^ y, (x & y) << 1
        s, c = jax.lax.fori_loop(0, bits * 2, add_body, (acc, pp))
        return s | c

    out_ref[...] = jax.lax.fori_loop(0, bits, body, acc)


def _run(kernel, a, b, block_rows, block_cols, interpret):
    rows, cols = a.shape
    block_rows = min(block_rows, rows)
    block_cols = min(block_cols, cols)
    assert rows % block_rows == 0 and cols % block_cols == 0
    grid = (rows // block_rows, cols // block_cols)
    spec = pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j))
    return pl.pallas_call(
        kernel, grid=grid, in_specs=[spec, spec], out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(a, b)


def bitserial_add(a: jnp.ndarray, b: jnp.ndarray, block_rows: int = 8,
                  block_cols: int = 512, interpret: bool = True):
    """Elementwise a+b via the bit-serial MAJ/XOR adder (int32/int8 tiles)."""
    bits = a.dtype.itemsize * 8
    return _run(functools.partial(_add_kernel, bits=bits), a, b,
                block_rows, block_cols, interpret)


def bitserial_mul(a: jnp.ndarray, b: jnp.ndarray, block_rows: int = 8,
                  block_cols: int = 512, interpret: bool = True):
    """Elementwise a*b via bit-serial shift-add partial products."""
    bits = a.dtype.itemsize * 8
    return _run(functools.partial(_mul_kernel, bits=bits), a, b,
                block_rows, block_cols, interpret)
