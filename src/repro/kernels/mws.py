"""Flash-Cosmos multi-wordline-sensing bulk bitwise ops as a Pallas kernel.

TPU adaptation (DESIGN.md §4a): the flash page (one wordline's 16 KiB row)
maps to a VMEM-tiled (sublane x lane)-aligned block; "simultaneously
activating multiple wordlines" — a wired-AND across the stacked cells of a
NAND string — becomes an in-register reduce over the operand-stacked
leading axis *inside one VMEM tile*: every operand page is touched exactly
once and never round-trips to HBM between operands, the TPU-native analogue
of computing during a single array sense.

Layout: ``stack[n_ops, rows, cols]`` -> out ``[rows, cols]``.  The grid
tiles (rows, cols); each invocation reduces all n_ops in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INIT = {"and": -1, "nand": -1, "or": 0, "nor": 0, "xor": 0}
_IS_AND = {"and", "nand"}
_NEGATE = {"nand", "nor"}


def _mws_kernel(stack_ref, out_ref, *, op: str, n_ops: int):
    acc = jnp.full(out_ref.shape, _INIT[op], dtype=out_ref.dtype)

    def body(i, acc):
        page = stack_ref[i]                       # one wordline's page
        if op in _IS_AND:
            return acc & page
        if op in ("or", "nor"):
            return acc | page
        return acc ^ page

    acc = jax.lax.fori_loop(0, n_ops, body, acc)
    if op in _NEGATE:
        acc = ~acc
    out_ref[...] = acc


def mws_bitwise(stack: jnp.ndarray, op: str = "and",
                block_rows: int = 8, block_cols: int = 512,
                interpret: bool = True) -> jnp.ndarray:
    """Bulk bitwise reduce over ``stack[n_ops, rows, cols]`` (int dtype).

    ``block_rows``/``block_cols`` define the VMEM tile; cols should be a
    multiple of 128 (TPU lane count) and rows a multiple of 8 (sublanes).
    """
    n_ops, rows, cols = stack.shape
    block_rows = min(block_rows, rows)
    block_cols = min(block_cols, cols)
    assert rows % block_rows == 0 and cols % block_cols == 0, \
        f"{rows}x{cols} not tileable by {block_rows}x{block_cols}"
    grid = (rows // block_rows, cols // block_cols)
    kernel = functools.partial(_mws_kernel, op=op, n_ops=n_ops)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(
            (n_ops, block_rows, block_cols),
            lambda i, j: (0, i, j))],
        out_specs=pl.BlockSpec(
            (block_rows, block_cols), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), stack.dtype),
        interpret=interpret,
    )(stack)
