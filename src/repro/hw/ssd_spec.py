"""Simulated SSD hardware parameters.

Faithful transcription of the paper's Table 2 ("Evaluated Configurations")
plus the latency/energy constants quoted in §4.5 and §5.2.  All latencies in
nanoseconds, all energies in nanojoules, all sizes in bytes unless noted.

The SSD modeled is a 2 TB 48-WL-layer 3D TLC NAND SSD (Samsung 980 Pro
class) with computation capability retrofitted per Flash-Cosmos [10],
Ares-Flash [201], MIMDRAM [26] and ARM Cortex-R8 ISP cores [216].
"""
from __future__ import annotations

import dataclasses

KiB = 1024
MiB = 1024 * KiB
GiB = 1024 * MiB

US = 1_000.0  # ns per microsecond
MS = 1_000_000.0


@dataclasses.dataclass(frozen=True)
class FlashSpec:
    """NAND geometry + timing (Table 2) and IFP compute primitives."""

    channels: int = 8
    dies_per_channel: int = 8
    planes_per_die: int = 2
    blocks_per_plane: int = 2048
    wls_per_block: int = 196          # 4 x 48 WL layers
    page_size: int = 16 * KiB         # NDP page == vector width (§4.3.1)
    # §4.3.1: -force-vector-width=4096 with 32-bit operands = 16 KiB, sized
    # to the NAND page so one vector operand == one logical page.  After the
    # INT8 quantization (§5.4) a page holds 16384 lanes; the SSD offloader
    # splits pages into smaller sub-operations for narrower resources
    # (handled inside each resource's latency model).
    channel_bw_GBps: float = 1.2      # flash channel bandwidth
    # SLC-mode latencies (Flash-Cosmos-calibrated)
    t_read_ns: float = 22.5 * US      # tR, SLC-mode sense of one page
    t_prog_ns: float = 400 * US       # SLC-mode program
    t_erase_ns: float = 3500 * US
    e_erase_nj_per_block: float = 150_000.0  # block erase energy (GC wear)
    # In-flash compute primitives
    t_and_or_ns: float = 20.0         # MWS AND/OR (per multi-WL sense, on top of tR)
    t_xor_ns: float = 30.0            # XOR via latch ops
    t_latch_transfer_ns: float = 20.0 # S-latch <-> D-latch move
    t_dma_ns: float = 3.3 * US        # page buffer -> flash controller DMA
    # Ares-Flash shift-and-add multiply: bit-serial over operand width.
    # One partial product = 1 latch AND + 1 shift + 1 add (latch transfers).
    shift_add_cycle_ns: float = 2 * 20.0 + 30.0  # latch xfer + xfer + xor-class add
    # Energy (Flash-Cosmos / ParaBit measured values)
    e_read_nj_per_channel: float = 20_500.0   # 20.5 uJ / channel page read
    e_and_or_nj_per_kb: float = 10.0
    e_latch_transfer_nj_per_kb: float = 10.0
    e_xor_nj_per_kb: float = 20.0
    e_dma_nj_per_channel: float = 7_656.0     # 7.656 uJ / channel DMA
    e_prog_nj_per_channel: float = 65_000.0   # SLC program energy (calibrated)

    @property
    def total_dies(self) -> int:
        return self.channels * self.dies_per_channel

    @property
    def total_planes(self) -> int:
        return self.total_dies * self.planes_per_die

    @property
    def channel_ns_per_byte(self) -> float:
        return 1.0 / (self.channel_bw_GBps)  # GB/s == bytes/ns

    @property
    def capacity_bytes(self) -> int:
        return (self.channels * self.dies_per_channel * self.planes_per_die
                * self.blocks_per_plane * self.wls_per_block * self.page_size)


@dataclasses.dataclass(frozen=True)
class SSDDRAMSpec:
    """SSD-internal LPDDR4 DRAM (Table 2) with PuD (MIMDRAM-class) compute."""

    capacity: int = 2 * GiB
    channels: int = 1
    ranks: int = 1
    banks: int = 8
    row_size: int = 8 * KiB           # one DRAM row / PuD vector fragment
    # LPDDR4-1866 core timings (ns)
    t_rcd_ns: float = 18.0
    t_rp_ns: float = 18.0
    t_ras_ns: float = 42.0
    t_ccd_ns: float = 4.3             # column-to-column
    bus_bw_GBps: float = 14.9         # 1866 MT/s x 8B
    # PuD compute: one bulk bitwise op (bbop) over a full row
    t_bbop_ns: float = 49.0           # MIMDRAM-calibrated triple-row-activation op
    e_bbop_nj: float = 0.864          # per row-op
    # bit-serial arithmetic: N-bit add = ~5N bbops, N-bit mul = ~2N^2+6N bbops
    # (SIMDRAM majority-based circuits); relational = ~2N bbops.
    e_act_pre_nj: float = 2.0         # activation+precharge energy per row
    e_bus_nj_per_kb: float = 4.0      # DRAM bus transfer energy

    @property
    def bus_ns_per_byte(self) -> float:
        return 1.0 / self.bus_bw_GBps


@dataclasses.dataclass(frozen=True)
class ISPSpec:
    """SSD controller embedded cores (ARM Cortex-R8, Table 2)."""

    cores: int = 5                     # 1 used for offloaded compute (§4.3.2 fn3)
    compute_cores: int = 1
    freq_ghz: float = 1.5
    simd_bytes: int = 16               # MVE/Helium: 128-bit vector datapath
    ipc: float = 1.0                   # sustained vector IPC (QEMU-calibrated)
    # energy: ARM R8-class core power ~ 0.25 W @1.5GHz
    power_w: float = 0.25
    # SRAM/DRAM access from core
    dram_access_ns: float = 100.0      # controller <-> SSD DRAM latency
    mem_bw_GBps: float = 4.0           # sustained core<->SSD-DRAM streaming bw

    @property
    def cycle_ns(self) -> float:
        return 1.0 / self.freq_ghz

    def vector_op_ns(self, num_bytes: int, cycles_per_vec: float = 1.0) -> float:
        """Latency for an elementwise SIMD op over num_bytes.

        The core is usually *memory-bound* streaming 2 loads + 1 store per
        element through its narrow DRAM port — the paper's "limited SIMD
        parallelism" of ISP (§2.2)."""
        vecs = max(1, (num_bytes + self.simd_bytes - 1) // self.simd_bytes)
        compute = vecs * cycles_per_vec * self.cycle_ns / self.ipc
        mem = 3.0 * num_bytes / self.mem_bw_GBps
        return max(compute, mem)

    def energy_nj(self, latency_ns: float) -> float:
        return self.power_w * latency_ns  # W * ns = nJ


@dataclasses.dataclass(frozen=True)
class FTLSpec:
    """Flash-translation-layer firmware parameters (page-mapping FTL).

    Real drives reserve physical capacity beyond the advertised logical
    space (over-provisioning) so the garbage collector always has somewhere
    to consolidate valid pages; the watermarks bound when the GC background
    process runs.  Fractions are of a die's physical page count — GC is a
    per-die activity in :mod:`repro.sim.ftl`.

    The policy knobs parameterize the GC policy suite of
    :mod:`repro.sim.ftl` (victim selection, hot/cold data separation,
    GC suspend/throttle); :class:`~repro.sim.ftl.FTLConfig` fields default
    to these firmware values and override them per run."""

    op_ratio: float = 0.28            # physical/logical - 1 (28% OP)
    gc_low_watermark: float = 0.10    # free-page fraction that wakes GC
    gc_high_watermark: float = 0.20   # free-page fraction where GC sleeps
    # hot/cold data separation: an LBA whose lifetime write count reaches
    # the threshold is routed to the hot host append point (hot pages die
    # together, so victims are either nearly-empty or nearly-full)
    hot_threshold: int = 3
    # wear-aware victim selection: valid-page-count penalty per erase the
    # candidate block sits above the die's least-worn block
    wear_alpha: float = 4.0
    # GC suspend/throttle: pause the collector between page copies while
    # the host has >= gc_suspend_qd requests outstanding, re-checking
    # every gc_backoff_ns
    gc_suspend_qd: int = 2
    gc_backoff_ns: float = 30_000.0

    def __post_init__(self) -> None:
        if not 0.0 < self.op_ratio:
            raise ValueError(f"op_ratio must be > 0, got {self.op_ratio}")
        if not 0.0 <= self.gc_low_watermark < self.gc_high_watermark <= 1.0:
            raise ValueError(
                "need 0 <= gc_low_watermark < gc_high_watermark <= 1, got "
                f"low={self.gc_low_watermark} high={self.gc_high_watermark}")
        if self.hot_threshold < 2:
            raise ValueError(
                f"hot_threshold must be >= 2, got {self.hot_threshold}")
        if self.wear_alpha < 0.0:
            raise ValueError(
                f"wear_alpha must be >= 0, got {self.wear_alpha}")
        # gc_suspend_qd / gc_backoff_ns are deliberately NOT validated
        # here: the suspend machinery checks them at model-build time
        # (see FTLModel) so a spec with suspend disabled may carry any
        # placeholder values, and tests pin that contract.


@dataclasses.dataclass(frozen=True)
class ReliabilitySpec:
    """ECC / read-recovery hardware constants (the *cost* side of the
    reliability model; the *error-rate* side is the seeded
    :class:`~repro.sim.faults.FaultConfig`).

    The hard-decode BCH/LDPC engine corrects up to an RBER of
    ``ecc_hard_rber`` essentially for free (decode latency is hidden in
    the channel transfer, as on real controllers).  Past it, recovery
    escalates through the classic ladder — read-retry re-senses at
    shifted reference voltages (each retry a real re-read of the die plus
    a channel transfer), then LDPC soft-decode on longer soft-sense data,
    then superpage-parity reconstruction across the stripe's sibling
    dies.  Every stage books real time on the contended pools."""

    ecc_hard_rber: float = 1e-3       # hard-decode correction limit (RBER)
    ecc_steepness: float = 4.0        # decode-failure curve sharpness
    read_retry_ns: float = 8_000.0    # extra sense time per retry step
    max_read_retries: int = 4         # voltage-shift retry steps
    retry_rber_factor: float = 0.5    # effective RBER shrink per retry step
    soft_decode_ns: float = 60_000.0  # LDPC soft-decode on the ECC engine
    soft_rber_factor: float = 0.05    # soft decode corrects ~20x harder reads
    ecc_engines: int = 2              # controller soft-decode/XOR engines
    rebuild_xor_ns_per_page: float = 2_000.0  # parity XOR per stripe page

    def __post_init__(self) -> None:
        if not 0.0 < self.ecc_hard_rber < 1.0:
            raise ValueError(
                f"ecc_hard_rber must be in (0, 1), got {self.ecc_hard_rber}")
        if self.ecc_steepness <= 0.0:
            raise ValueError(
                f"ecc_steepness must be > 0, got {self.ecc_steepness}")
        if self.read_retry_ns < 0.0 or self.soft_decode_ns < 0.0 \
                or self.rebuild_xor_ns_per_page < 0.0:
            raise ValueError("reliability latencies must be >= 0")
        if self.max_read_retries < 0:
            raise ValueError(
                f"max_read_retries must be >= 0, got {self.max_read_retries}")
        if not 0.0 < self.retry_rber_factor <= 1.0:
            raise ValueError("retry_rber_factor must be in (0, 1], got "
                             f"{self.retry_rber_factor}")
        if not 0.0 < self.soft_rber_factor <= 1.0:
            raise ValueError("soft_rber_factor must be in (0, 1], got "
                             f"{self.soft_rber_factor}")
        if self.ecc_engines < 1:
            raise ValueError(
                f"ecc_engines must be >= 1, got {self.ecc_engines}")


@dataclasses.dataclass(frozen=True)
class HostSpec:
    """Host CPU/GPU + interconnect (Table 2).

    CPU: Xeon Gold 5118 (6 cores OoO 3.2 GHz, AVX-512-class 64B SIMD).
    GPU: NVIDIA A100 (108 SMs @ 1.4 GHz).
    PCIe 4.0 x4-class external bandwidth: 8 GB/s.
    Host DRAM: DDR4-2400 4ch, 19.2 GB/s.
    """

    pcie_bw_GBps: float = 8.0
    pcie_latency_ns: float = 1_000.0
    host_dram_bw_GBps: float = 19.2
    cpu_cores: int = 6
    cpu_freq_ghz: float = 3.2
    cpu_simd_bytes: int = 64
    cpu_ipc: float = 2.0               # dual-issue vector pipelines
    cpu_power_w: float = 105.0
    gpu_sms: int = 108
    gpu_freq_ghz: float = 1.4
    gpu_lanes_per_sm: int = 64         # FP32/INT cores per SM
    gpu_power_w: float = 300.0
    gpu_hbm_bw_GBps: float = 1555.0
    e_pcie_nj_per_kb: float = 150.0    # link + controller energy
    e_host_dram_nj_per_kb: float = 30.0

    @property
    def pcie_ns_per_byte(self) -> float:
        return 1.0 / self.pcie_bw_GBps

    def cpu_vector_op_ns(self, num_bytes: int, cycles_per_vec: float = 1.0) -> float:
        per_core = 1.0 / (self.cpu_freq_ghz * self.cpu_ipc)
        vecs = max(1, (num_bytes + self.cpu_simd_bytes - 1) // self.cpu_simd_bytes)
        return vecs * cycles_per_vec * per_core / self.cpu_cores

    def gpu_vector_op_ns(self, num_bytes: int, cycles_per_vec: float = 1.0) -> float:
        lanes = self.gpu_sms * self.gpu_lanes_per_sm  # 4-byte lanes
        elems = max(1, num_bytes // 4)
        waves = max(1, (elems + lanes - 1) // lanes)
        return waves * cycles_per_vec / self.gpu_freq_ghz


@dataclasses.dataclass(frozen=True)
class SSDSpec:
    flash: FlashSpec = dataclasses.field(default_factory=FlashSpec)
    dram: SSDDRAMSpec = dataclasses.field(default_factory=SSDDRAMSpec)
    isp: ISPSpec = dataclasses.field(default_factory=ISPSpec)
    host: HostSpec = dataclasses.field(default_factory=HostSpec)
    ftl: FTLSpec = dataclasses.field(default_factory=FTLSpec)
    reliability: ReliabilitySpec = dataclasses.field(
        default_factory=ReliabilitySpec)
    # Conduit runtime overheads (§4.5)
    l2p_lookup_dram_ns: float = 100.0
    l2p_lookup_flash_ns: float = 30.0 * US
    dep_delay_track_ns: float = 1.0 * US     # per queue
    queue_delay_track_ns: float = 1.0 * US   # per resource
    dm_latency_lookup_ns: float = 100.0
    comp_latency_lookup_ns: float = 150.0
    translation_lookup_ns: float = 300.0
    translation_table_bytes: int = int(1.5 * KiB)

    @property
    def page_size(self) -> int:
        return self.flash.page_size


DEFAULT_SSD = SSDSpec()
