"""Target TPU hardware constants for roofline analysis.

The container is CPU-only; TPU v5e is the *target*.  These constants feed
the three-term roofline derived from the compiled dry-run artifacts:

  compute term    = HLO_FLOPs       / (chips * peak_flops)
  memory term     = HLO_bytes       / (chips * hbm_bw)
  collective term = collective_bytes/ (chips * ici_bw)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TPUSpec:
    name: str = "tpu_v5e"
    peak_bf16_flops: float = 197e12      # FLOP/s per chip
    hbm_bw: float = 819e9                # bytes/s per chip
    hbm_bytes: float = 16e9              # HBM capacity per chip
    ici_bw_per_link: float = 50e9        # bytes/s per ICI link
    ici_links: int = 4                   # 2D torus: 4 links/chip
    dcn_bw: float = 25e9 / 8             # inter-pod (data-center network), bytes/s/chip
    vmem_bytes: float = 128e6 / 1        # ~128MB vector memory (v5e: 128MiB shared)
    mxu_dim: int = 128                   # systolic array edge
    lane_count: int = 128                # VPU lanes
    sublane_count: int = 8

    @property
    def ici_bw(self) -> float:
        # Bisection-style per-chip collective bandwidth: a well-scheduled
        # ring/torus all-reduce streams over all links concurrently, but we
        # use the conservative single-direction per-link figure times 2
        # (bidirectional ring) as the per-chip collective bandwidth.
        return self.ici_bw_per_link * 2

    def roofline_terms(self, flops: float, hbm_bytes: float,
                       collective_bytes: float, chips: int) -> dict:
        """Return the three roofline terms in seconds (per-step)."""
        ct = flops / (chips * self.peak_bf16_flops)
        mt = hbm_bytes / (chips * self.hbm_bw)
        xt = collective_bytes / (chips * self.ici_bw)
        dominant = max((ct, "compute"), (mt, "memory"), (xt, "collective"))[1]
        return {
            "compute_s": ct,
            "memory_s": mt,
            "collective_s": xt,
            "dominant": dominant,
            "bound_s": max(ct, mt, xt),
        }


TPU_V5E = TPUSpec()
