"""Hardware models: simulated SSD (paper Table 2) and target TPU v5e constants."""
from repro.hw.ssd_spec import SSDSpec, DEFAULT_SSD
from repro.hw.tpu_spec import TPUSpec, TPU_V5E

__all__ = ["SSDSpec", "DEFAULT_SSD", "TPUSpec", "TPU_V5E"]
