"""LR schedules.  minicpm-2b trains with WSD (warmup-stable-decay,
arXiv:2404.06395); everything else defaults to cosine."""
from __future__ import annotations

import jax.numpy as jnp


def wsd_schedule(step, base_lr: float, warmup: int, stable: int, decay: int,
                 final_frac: float = 0.1):
    """Warmup-Stable-Decay: linear warmup, flat plateau, 1-cycle decay."""
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * jnp.minimum(1.0, (step + 1) / jnp.maximum(1, warmup))
    in_decay = jnp.clip((step - warmup - stable) / jnp.maximum(1, decay),
                        0.0, 1.0)
    decay_mult = 1.0 - (1.0 - final_frac) * in_decay
    return jnp.where(step < warmup + stable, warm, base_lr * decay_mult)


def cosine_schedule(step, base_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * jnp.minimum(1.0, (step + 1) / jnp.maximum(1, warmup))
    t = jnp.clip((step - warmup) / jnp.maximum(1, total - warmup), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup, warm, base_lr * cos)


def make_schedule(kind: str, base_lr: float, total_steps: int,
                  warmup: int | None = None):
    warmup = warmup if warmup is not None else max(10, total_steps // 50)
    if kind == "wsd":
        stable = int(0.8 * (total_steps - warmup))
        decay = total_steps - warmup - stable
        return lambda s: wsd_schedule(s, base_lr, warmup, stable, decay)
    return lambda s: cosine_schedule(s, base_lr, warmup, total_steps)
