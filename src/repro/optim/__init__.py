from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule, wsd_schedule, make_schedule
from repro.optim.compress import (compress_int8, decompress_int8,
                                  error_feedback_update)

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_schedule",
           "wsd_schedule", "make_schedule", "compress_int8",
           "decompress_int8", "error_feedback_update"]
