"""AdamW with fp32 moments over (possibly bf16) params, global-norm clip.

State layout keeps moments in the same pytree structure as params so
jit/pjit shards them identically to the weights (ZeRO-style when the caller
adds a sharding rule over the data axis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params, grads, state: AdamWState, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}
