"""INT8 gradient compression with error feedback (distributed-optimization
trick for cross-pod gradient reduction).

The paper quantizes all workload data to INT8 to fit SSD compute (§5.4); we
apply the same idea to the slowest link of the production mesh — the
inter-pod "pod" axis — by quantizing gradients to INT8 (per-tensor scale)
before the cross-pod all-reduce and carrying the quantization residual into
the next step (error feedback keeps convergence unbiased).

4x less DCN traffic; the residual buffer shares the gradient's sharding.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric INT8 quantization; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def error_feedback_update(grads: Any, residuals: Any) -> Tuple[Any, Any]:
    """Quantize (grads + residuals) to INT8; return (dequantized grads for
    the optimizer, new residuals).  Applied leaf-wise over the pytree."""

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, s = compress_int8(corrected)
        deq = decompress_int8(q, s)
        return deq.astype(g.dtype), corrected - deq

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    new_r = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return new_g, new_r


def init_residuals(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
