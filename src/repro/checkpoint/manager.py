"""Checkpoint/restart for fault tolerance.

Layout per step::

    <dir>/step_000123/
        arrays.npz          flattened pytree leaves (keyed by index)
        manifest.json       treedef repr, shapes/dtypes, content hash, step
    <dir>/LATEST            atomic pointer file (written last)

Writes go to a temp dir then ``os.replace`` — a crash mid-save never
corrupts the previous checkpoint, and LATEST only advances after the
payload is fully durable.  ``CheckpointManager`` adds async saves (a
background thread), retention, and restore-with-validation (content hash +
shape/dtype check).  Restores compose with the stateless data pipeline:
resuming at step N replays the exact stream.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return [np.asarray(l) for l in leaves], treedef


def _content_hash(arrays: list) -> str:
    # bytes+shape only: exotic dtypes (bfloat16) round-trip through npz as
    # raw void arrays, so dtype strings are validated via the manifest
    h = hashlib.sha256()
    for a in arrays:
        h.update(str(tuple(a.shape)).encode())
        h.update(a.tobytes()[:65536])     # prefix hash: fast + catches corruption
    return h.hexdigest()


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    arrays, treedef = _flatten(tree)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"leaf_{i}": a for i, a in enumerate(arrays)})
    manifest = {
        "step": step,
        "n_leaves": len(arrays),
        "treedef": str(treedef),
        "shapes": [list(a.shape) for a in arrays],
        "dtypes": [str(a.dtype) for a in arrays],
        "hash": _content_hash(arrays),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    latest_tmp = os.path.join(directory, ".LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(f"step_{step:09d}")
    os.replace(latest_tmp, os.path.join(directory, "LATEST"))
    return final


def load_checkpoint(directory: str, template: Any,
                    step: Optional[int] = None) -> Tuple[Any, dict]:
    """Restore into the structure of ``template`` (validates shapes/dtypes
    and the content hash).  ``step=None`` loads LATEST."""
    if step is None:
        with open(os.path.join(directory, "LATEST")) as f:
            name = f.read().strip()
    else:
        name = f"step_{step:09d}"
    path = os.path.join(directory, name)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"), allow_pickle=False)
    arrays = []
    for i in range(manifest["n_leaves"]):
        a = data[f"leaf_{i}"]
        want = manifest["dtypes"][i]
        if str(a.dtype) != want:
            # npz stores exotic dtypes (bfloat16) as raw void: re-view
            import ml_dtypes  # noqa: F401  (registers bfloat16 et al.)
            a = a.view(np.dtype(want))
        arrays.append(a)
    if _content_hash(arrays) != manifest["hash"]:
        raise IOError(f"checkpoint {path} failed content-hash validation")
    t_leaves, treedef = jax.tree_util.tree_flatten(template)
    if len(t_leaves) != len(arrays):
        raise ValueError(
            f"checkpoint has {len(arrays)} leaves, template expects "
            f"{len(t_leaves)}")
    for i, (t, a) in enumerate(zip(t_leaves, arrays)):
        if tuple(t.shape) != tuple(a.shape):
            raise ValueError(f"leaf {i}: shape {a.shape} != {t.shape}")
    restored = [np.asarray(a).astype(t.dtype) if a.shape else
                np.asarray(a).astype(t.dtype).reshape(())
                for t, a in zip(t_leaves, arrays)]
    return jax.tree_util.tree_unflatten(treedef, restored), manifest


class CheckpointManager:
    """Async checkpointing with retention — save() returns immediately;
    wait() joins the in-flight write (called before exit / next save)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             blocking: bool = False) -> None:
        self.wait()
        # materialize on host before handing to the writer thread
        host_tree = jax.tree_util.tree_map(np.asarray, tree)

        def work():
            save_checkpoint(self.directory, step, host_tree, extra)
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def _gc(self) -> None:
        steps = sorted(d for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, d),
                          ignore_errors=True)

    def latest_step(self) -> Optional[int]:
        try:
            with open(os.path.join(self.directory, "LATEST")) as f:
                return int(f.read().strip().split("_")[1])
        except (FileNotFoundError, IndexError, ValueError):
            return None

    def restore(self, template: Any, step: Optional[int] = None):
        return load_checkpoint(self.directory, template, step)
